"""SolveEngine end-to-end: micro-batching, demux fidelity, compile
accounting, timeout flush, and the acceptance contract — a mixed-size
stream of ≥ 64 instances served with at most (buckets × routes × ladder
rungs) compilations and per-request results bit-identical to a direct
``api.solve`` of the same bucket-padded instance. (Async-specific
behaviour — harvest, backpressure, deadlines, adaptive routing — lives
in tests/test_serve_async.py.)"""
import numpy as np
import pytest

from repro import api
from repro.core.graph import random_instance
from repro.core.solver import SolverConfig
from repro.serve import (
    BucketPolicy, Route, Router, RoutingRule, SolveEngine, batch_ladder,
    pad_instance,
)

# cheap configs so 64+ solves stay fast on CPU runners
CFG_DENSE = SolverConfig(max_neg=32, mp_iters=2, max_rounds=4,
                         graph_impl="dense")
CFG_SPARSE = SolverConfig(max_neg=32, mp_iters=2, max_rounds=4,
                          graph_impl="sparse", sparse_row_cap=64)
POLICY = BucketPolicy(node_floor=16, edge_floor=64)


def _router():
    """Two routes: small instances dense, larger ones sparse — so the
    mixed stream genuinely exercises multi-route dispatch."""
    return Router(rules=[RoutingRule(route=Route(mode="pd",
                                                 config=CFG_DENSE),
                                     max_nodes=24)],
                  default=Route(mode="pd", config=CFG_SPARSE))


def _mixed_stream(n: int):
    rng = np.random.default_rng(7)
    out = []
    for s in range(n):
        nodes = int(rng.integers(8, 48))
        out.append(random_instance(nodes, 0.4, seed=s))
    return out


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def advance(self, dt):
        self.t += dt

    def __call__(self):
        return self.t


def _bit_eq(a, b):
    return np.asarray(a).tobytes() == np.asarray(b).tobytes()


# ---------------------------------------------------------------------------
# the acceptance contract
# ---------------------------------------------------------------------------

def test_mixed_stream_end_to_end():
    api.clear_cache()
    eng = SolveEngine(router=_router(), policy=POLICY, batch_cap=8,
                      flush_timeout_s=None)
    insts = _mixed_stream(64)
    results = eng.solve_stream(insts)
    assert len(results) == 64
    assert eng.pending == 0
    assert eng.stats.n_completed == 64

    # compile budget: at most one executable per (bucket, route) per
    # sub-batch ladder rung actually dispatched; at least one per key
    keys = {(POLICY.bucket_of(i), eng.router.route_instance(i))
            for i in insts}
    routes = {k[1] for k in keys}
    assert len(routes) == 2                      # stream spans both routes
    rungs = len(batch_ladder(eng.batch_cap))
    assert len(keys) <= eng.stats.compiles <= len(keys) * rungs
    # the ladder's payoff: partial flushes decompose instead of padding
    assert eng.stats.n_filler_slots == 0
    assert eng.stats.occupancy == 1.0

    # per-request results bit-identical to the direct solve of the same
    # bucket-padded instance (same executable family, vmap is bit-preserving)
    for inst, res in zip(insts, results):
        bucket = POLICY.bucket_of(inst)
        route = eng.router.route_instance(inst)
        direct = api.solve(pad_instance(inst, bucket), mode=route.mode,
                           config=route.config, backend=route.backend)
        assert _bit_eq(res.objective, direct.objective)
        assert _bit_eq(res.lower_bound, direct.lower_bound)
        assert _bit_eq(res.lb_history, direct.lb_history)
        assert int(res.rounds) == int(direct.rounds)
        assert np.array_equal(np.asarray(res.labels),
                              np.asarray(direct.labels)[:inst.num_nodes])
        # demux stripped the node padding back to the request's own shape
        assert res.labels.shape == (inst.num_nodes,)


def test_results_identical_to_unpadded_solve_given_headroom():
    """The serving layer adds padding + batching only: engine results match
    a plain per-instance api.solve bit-exactly whenever the instance
    already has non-binding chord headroom (padding neutrality, pinned in
    test_serve_buckets; instances arriving *full* can only improve)."""
    eng = SolveEngine(router=_router(), policy=POLICY, batch_cap=4,
                      flush_timeout_s=None)
    rng = np.random.default_rng(3)
    insts = [random_instance(int(rng.integers(8, 32)), 0.4, seed=s,
                             pad_edges=512) for s in range(8)]
    for inst, res in zip(insts, eng.solve_stream(insts)):
        route = eng.router.route_instance(inst)
        plain = api.solve(inst, mode=route.mode, config=route.config)
        assert _bit_eq(res.objective, plain.objective)
        assert np.array_equal(np.asarray(res.labels),
                              np.asarray(plain.labels)[:inst.num_nodes])


# ---------------------------------------------------------------------------
# batching mechanics
# ---------------------------------------------------------------------------

def test_full_queue_dispatches_on_submit():
    eng = SolveEngine(router=_router(), policy=POLICY, batch_cap=4,
                      flush_timeout_s=None)
    same_bucket = [random_instance(12, 0.5, seed=s, pad_edges=64,
                                   pad_nodes=16) for s in range(4)]
    tickets = [eng.submit(i) for i in same_bucket]
    # 4th submit filled the batch — dispatched without any flush
    assert eng.stats.n_dispatches == 1
    assert eng.pending == 0
    eng.drain()                        # harvest the in-flight window
    assert all(t.done for t in tickets)
    assert eng.stats.n_filler_slots == 0
    assert eng.stats.occupancy == 1.0


def test_timeout_flush_with_fake_clock():
    clock = FakeClock()
    # max_inflight=0: the synchronous engine, so `done` flips inside the
    # pump that dispatches (the async window is exercised elsewhere)
    eng = SolveEngine(router=_router(), policy=POLICY, batch_cap=8,
                      flush_timeout_s=0.5, clock=clock, max_inflight=0)
    t = eng.submit(random_instance(12, 0.5, seed=0, pad_edges=64,
                                   pad_nodes=16))
    assert not t.done and eng.pending == 1
    clock.advance(0.4)
    assert eng.pump() == 0                     # not timed out yet
    assert not t.done
    clock.advance(0.2)
    assert eng.pump() == 1                     # 0.6s > 0.5s: partial flush
    assert t.done
    # the sub-batch ladder dispatched a 1-slot batch, not cap-padded
    assert eng.stats.n_filler_slots == 0
    assert eng.stats.occupancy == 1.0
    assert t.latency_s == pytest.approx(0.6)


def test_ticket_result_forces_its_queue():
    eng = SolveEngine(router=_router(), policy=POLICY, batch_cap=8,
                      flush_timeout_s=None)
    t = eng.submit(random_instance(12, 0.5, seed=0, pad_edges=64,
                                   pad_nodes=16))
    assert not t.done
    res = t.result()                           # blocks by force-flushing
    assert t.done and res.labels.shape == (16,)


def test_solve_stream_preserves_submission_order():
    eng = SolveEngine(router=_router(), policy=POLICY, batch_cap=4,
                      flush_timeout_s=None)
    insts = _mixed_stream(12)
    results = eng.solve_stream(insts)
    for inst, res in zip(insts, results):
        assert res.labels.shape == (inst.num_nodes,)


def test_warmup_precompiles():
    api.clear_cache()
    eng = SolveEngine(router=_router(), policy=POLICY, batch_cap=4,
                      flush_timeout_s=None)
    shapes = [(i.num_nodes, i.num_edges) for i in _mixed_stream(16)]
    fresh = eng.warmup(shapes)
    assert fresh == eng.stats.compiles > 0
    # serving the same shapes afterwards costs zero additional compiles
    before = eng.stats.compiles
    eng.solve_stream(_mixed_stream(16))
    assert eng.stats.compiles == before


def test_oversized_instance_rejected_at_admission():
    eng = SolveEngine(router=_router(),
                      policy=BucketPolicy(node_floor=16, edge_floor=64,
                                          node_cap=32),
                      batch_cap=4)
    with pytest.raises(ValueError):
        eng.submit(random_instance(40, 0.3, seed=0))
    assert eng.pending == 0


def test_batch_cap_must_split_across_shards():
    eng = SolveEngine(router=_router(), policy=POLICY, batch_cap=3)
    inst = random_instance(12, 0.5, seed=0, pad_edges=64, pad_nodes=16)
    if __import__("jax").device_count() >= 2:
        with pytest.raises(ValueError):
            eng.submit(inst, route=Route(mode="pd", config=CFG_DENSE,
                                         batch_shards=2))
    else:       # clamped to 1 device: divisibility trivially holds
        eng.submit(inst, route=Route(mode="pd", config=CFG_DENSE,
                                     batch_shards=2))


def test_pinned_route_overrides_router():
    eng = SolveEngine(router=_router(), policy=POLICY, batch_cap=2,
                      flush_timeout_s=None)
    inst = random_instance(12, 0.5, seed=0, pad_edges=64, pad_nodes=16)
    pinned = Route(mode="p", config=CFG_DENSE)
    t = eng.submit(inst, route=pinned)
    assert t.route == pinned
    res = t.result()
    direct = api.solve(pad_instance(inst, t.bucket), mode="p",
                       config=CFG_DENSE)
    assert _bit_eq(res.objective, direct.objective)


# ---------------------------------------------------------------------------
# scheduler edge cases: empty ticks and filler-only batches
# ---------------------------------------------------------------------------

def test_pump_empty_queues_dispatches_nothing():
    """An idle tick is a no-op: no dispatch, no filler work, no compile."""
    eng = SolveEngine(router=_router(), policy=POLICY, batch_cap=4,
                      flush_timeout_s=0.0)
    assert eng.pump() == 0
    assert eng.pump(force=True) == 0
    assert eng.flush() == 0
    assert eng.flush_deltas() == 0
    assert eng.stats.n_dispatches == 0
    assert eng.stats.n_delta_dispatches == 0
    assert eng.stats.n_filler_slots == 0
    assert eng.stats.compiles == 0


def test_flush_unknown_key_is_noop():
    eng = SolveEngine(router=_router(), policy=POLICY, batch_cap=4)
    inst = random_instance(12, 0.5, seed=0, pad_edges=64, pad_nodes=16)
    bucket = eng.policy.bucket_of(inst)
    route = eng.router.route_instance(inst)
    assert eng.flush((bucket, route)) == 0
    assert eng.flush_deltas((bucket, route, True)) == 0
    assert eng.stats.n_dispatches == 0


def test_no_filler_only_batches_after_drain():
    """Once every ticket has resolved, further ticks must never dispatch a
    batch made purely of filler slots."""
    eng = SolveEngine(router=_router(), policy=POLICY, batch_cap=4,
                      flush_timeout_s=0.0)
    insts = _mixed_stream(3)
    tickets = eng.submit_many(insts)
    for t in tickets:
        t.result()
    dispatches = eng.stats.n_dispatches
    fillers = eng.stats.n_filler_slots
    assert eng.pending == 0
    # timeout 0.0 makes every non-empty queue eligible — but the queues
    # are drained, so nothing may go out
    assert eng.pump() == 0
    assert eng.pump(force=True) == 0
    assert eng.flush() == 0
    assert eng.stats.n_dispatches == dispatches
    assert eng.stats.n_filler_slots == fillers


def test_no_filler_only_delta_batches_after_drain():
    eng = SolveEngine(router=_router(), policy=POLICY, batch_cap=4,
                      flush_timeout_s=0.0, patch_cap=4)
    inst = random_instance(12, 0.5, seed=3, pad_edges=64, pad_nodes=16)
    s = eng.open_session(inst, warm=False)
    ev = np.asarray(inst.edge_valid)
    patch = api.make_patch(
        inst.num_nodes,
        reweight=([int(np.asarray(inst.u)[ev][0])],
                  [int(np.asarray(inst.v)[ev][0])], [2.5]))
    eng.submit_delta(s.session_id, patch).result()
    dispatches = eng.stats.n_delta_dispatches
    fillers = eng.stats.n_delta_filler_slots
    assert eng.pump(force=True) == 0
    assert eng.flush_deltas() == 0
    assert eng.flush_deltas(s.key) == 0
    assert eng.stats.n_delta_dispatches == dispatches
    assert eng.stats.n_delta_filler_slots == fillers
