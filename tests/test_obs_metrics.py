"""Metrics registry (PR 10): counters, gauges, log-bucketed histograms.

The contract the serving tier leans on: a :class:`Histogram` is a drop-in
replacement for the old 65536-entry latency deque — O(1) memory in the
stream length, exact count/sum/min/max, and a quantile estimate whose
relative error is provably below ``growth - 1`` (≤ 9.06% at the default
growth) no matter how many samples were observed. Plus the two machine
formats every metric must speak: the JSON snapshot and the Prometheus
text exposition.
"""
import json
import math
import random

import pytest

from repro.obs.metrics import (Counter, DEFAULT_GROWTH, Gauge, Histogram,
                               MetricsRegistry, quantile_error_bound)


# ---------------------------------------------------------------------------
# Histogram: quantile error bound + bounded memory
# ---------------------------------------------------------------------------

def test_histogram_quantile_error_bound_holds():
    """For in-range samples, the estimate brackets the true order
    statistic from above by at most the proven factor ``growth``."""
    rng = random.Random(0)
    h = Histogram("lat")
    samples = [10.0 ** rng.uniform(-3.5, 2.5) for _ in range(5000)]
    for s in samples:
        h.observe(s)
    samples.sort()
    bound = quantile_error_bound(h.growth)
    for q in (0.01, 0.10, 0.25, 0.50, 0.75, 0.90, 0.99, 1.0):
        rank = max(int(math.ceil(q * len(samples))) - 1, 0)
        true = samples[rank]
        est = h.quantile(q)
        assert est >= true * (1.0 - 1e-9), (q, true, est)
        assert est <= true * (1.0 + bound) * (1.0 + 1e-9), (q, true, est)


def test_histogram_memory_is_bounded():
    h = Histogram("lat")
    before = h.n_buckets
    for i in range(20000):
        h.observe(1e-5 + i * 0.01)
    assert h.n_buckets == before      # fixed bucket array, no growth
    assert before < 300               # "a couple hundred ints"


def test_histogram_exact_scalars_and_clamp():
    h = Histogram("lat")
    vals = [0.002, 0.004, 0.008, 0.5, 2.0]
    for v in vals:
        h.observe(v)
    assert h.count == len(vals)
    assert h.sum == pytest.approx(sum(vals))
    assert h.mean == pytest.approx(sum(vals) / len(vals))
    # quantiles clamp to the exact observed range
    assert h.quantile(0.0) >= min(vals)
    assert h.quantile(1.0) <= max(vals) * (1 + 1e-12)


def test_histogram_out_of_range_samples_still_counted():
    h = Histogram("lat", lo=1e-3, hi=1.0)
    h.observe(1e-9)     # underflow
    h.observe(100.0)    # overflow
    assert h.count == 2
    # the underflow bucket reports its upper edge ``lo`` (still an
    # overestimate, as the bound promises); overflow reports the exact max
    assert h.quantile(0.0) == pytest.approx(h.lo)
    assert h.quantile(1.0) == pytest.approx(100.0)
    assert h._min == pytest.approx(1e-9)    # exact scalars keep the truth


def test_histogram_empty_is_nan_and_bad_args_raise():
    h = Histogram("lat")
    assert math.isnan(h.quantile(0.5))
    assert math.isnan(h.mean)
    with pytest.raises(ValueError):
        h.quantile(1.5)
    with pytest.raises(ValueError):
        Histogram("bad", lo=1.0, hi=0.5)
    with pytest.raises(ValueError):
        Histogram("bad", growth=1.0)


# ---------------------------------------------------------------------------
# Counter / Gauge
# ---------------------------------------------------------------------------

def test_counter_monotone():
    c = Counter("reqs")
    c.inc()
    c.inc(2.5)
    assert c.value == pytest.approx(3.5)
    with pytest.raises(ValueError):
        c.inc(-1)


def test_gauge_callback_reads_live_value():
    box = [0]
    g = Gauge("depth", fn=lambda: box[0])
    assert g.value == 0
    box[0] = 7
    assert g.value == 7
    with pytest.raises(ValueError):
        g.set(3.0)        # callback-backed gauges reject set()
    plain = Gauge("plain")
    plain.set(2.0)
    assert plain.value == 2.0


# ---------------------------------------------------------------------------
# Registry: get-or-create, adoption, exposition formats
# ---------------------------------------------------------------------------

def test_registry_get_or_create_is_idempotent():
    reg = MetricsRegistry()
    c1 = reg.counter("reqs", "total requests")
    c2 = reg.counter("reqs")
    assert c1 is c2
    with pytest.raises(ValueError):
        reg.gauge("reqs")     # same name, different kind


def test_registry_register_adopts_external_metric():
    reg = MetricsRegistry()
    h = Histogram("request_latency_seconds")
    assert reg.register(h) is h
    assert reg.get("request_latency_seconds") is h
    assert reg.register(h) is h     # re-adopting the same object is fine
    with pytest.raises(ValueError):
        reg.register(Histogram("request_latency_seconds"))


def test_registry_json_snapshot_roundtrips():
    reg = MetricsRegistry()
    reg.counter("reqs").inc(3)
    reg.gauge("depth").set(2.0)
    h = reg.histogram("lat")
    for v in (0.01, 0.02, 0.04):
        h.observe(v)
    snap = json.loads(reg.to_json())
    assert snap["reqs"] == {"type": "counter", "value": 3}
    assert snap["depth"]["value"] == 2.0
    assert snap["lat"]["count"] == 3
    assert snap["lat"]["error_bound"] == pytest.approx(
        quantile_error_bound(DEFAULT_GROWTH))
    assert snap["lat"]["p50"] >= snap["lat"]["min"]


def _parse_prometheus(text: str) -> dict:
    """Minimal v0.0.4 parser: {sample_name_with_labels: float}."""
    typed = set()
    samples = {}
    for line in text.splitlines():
        if not line:
            continue
        if line.startswith("# TYPE"):
            typed.add(line.split()[2])
            continue
        if line.startswith("#"):
            continue
        name, val = line.rsplit(" ", 1)
        base = name.split("{")[0]
        for suffix in ("_bucket", "_sum", "_count"):
            if base.endswith(suffix):
                base = base[:-len(suffix)]
                break
        assert base in typed, f"sample {name} has no # TYPE"
        samples[name] = float(val.replace("+Inf", "inf"))
    return samples


def test_registry_prometheus_exposition_parses():
    reg = MetricsRegistry()
    reg.counter("reqs", "total").inc(5)
    reg.gauge("depth", "queue depth").set(1.0)
    h = reg.histogram("lat", "latency")
    for v in (0.01, 0.02, 0.04, 50.0):
        h.observe(v)
    samples = _parse_prometheus(reg.to_prometheus())
    assert samples["reqs"] == 5.0
    assert samples["depth"] == 1.0
    assert samples["lat_count"] == 4.0
    assert samples["lat_sum"] == pytest.approx(50.07)
    # cumulative buckets are non-decreasing and end at count on +Inf
    buckets = [(k, v) for k, v in samples.items()
               if k.startswith("lat_bucket")]
    vals = [v for _, v in buckets]
    assert vals == sorted(vals)
    assert samples['lat_bucket{le="+Inf"}'] == 4.0


def test_metric_names_sanitized_for_prometheus():
    reg = MetricsRegistry()
    reg.counter("serve.requests-total").inc()
    text = reg.to_prometheus()
    assert "serve_requests_total 1" in text


# ---------------------------------------------------------------------------
# Compile-budget gauges (satellite f): api.trace_count / cache_info
# ---------------------------------------------------------------------------

def test_register_compile_metrics_reads_live_api_counters():
    from repro import api
    from repro.obs import register_compile_metrics

    reg = register_compile_metrics(MetricsRegistry())
    snap = reg.snapshot()
    for name in ("compile_traces_total", "compile_cache_hits",
                 "compile_cache_misses", "compile_cache_size"):
        assert snap[name]["type"] == "gauge"
    assert snap["compile_traces_total"]["value"] == api.trace_count()
    assert snap["compile_cache_size"]["value"] == api.cache_info().currsize
