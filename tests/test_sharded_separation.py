"""Sharded separation: ``separation_shards`` splits the repulsive chunk
axis across devices via shard_map and must be bit-identical to the
single-device solve. Multi-device cases run in subprocesses so the parent
process keeps its single real CPU device (XLA device count is locked at
first jax init); CI additionally runs this file inside a 4-virtual-device
job so the in-process path is exercised too."""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro import api
from repro.core.cycles import resolve_separation_shards
from repro.core.graph import random_instance
from repro.core.solver import SolverConfig

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_shards_clamp_to_device_count():
    """A shards request beyond the devices present degrades to fewer shards
    instead of failing — presets with shards=4 stay runnable anywhere."""
    assert resolve_separation_shards(1) == 1
    assert resolve_separation_shards(0) == 1
    n = jax.device_count()
    assert resolve_separation_shards(10 ** 6) == n


def test_sharded_preset_solves_on_any_device_count():
    """pd-sharded must produce the same result as pd-sparse even when the
    runner has a single device (shards clamp to 1)."""
    inst = random_instance(48, 0.25, seed=0, pad_edges=1024, pad_nodes=64)
    r_ref = api.solve(inst, preset="pd-chunked")
    r_sh = api.solve(inst, preset="pd-sharded")
    assert np.asarray(r_ref.labels).tolist() == \
        np.asarray(r_sh.labels).tolist()
    assert float(r_ref.objective) == float(r_sh.objective)


def test_sharded_solve_bit_identical_4_devices():
    """On 4 virtual devices: shards ∈ {2, 4} solves bit-match the
    single-shard solve — labels, objective, LB, and round counts."""
    stdout = _run("""
        import dataclasses
        import numpy as np
        import jax
        from repro import api
        from repro.core.graph import random_instance
        from repro.core.solver import SolverConfig

        assert jax.device_count() == 4, jax.device_count()
        inst = random_instance(48, 0.25, seed=3, pad_edges=1024,
                               pad_nodes=64)
        base = SolverConfig(graph_impl="sparse", max_neg=64,
                            separation_chunk=8)
        ref = api.solve(inst, mode="pd+", config=base)
        for shards in (2, 4):
            cfg = dataclasses.replace(base, separation_shards=shards)
            r = api.solve(inst, mode="pd+", config=cfg)
            assert np.asarray(r.labels).tolist() == \\
                np.asarray(ref.labels).tolist(), shards
            assert float(r.objective) == float(ref.objective), shards
            assert float(r.lower_bound) == float(ref.lower_bound), shards
            assert int(r.rounds) == int(ref.rounds), shards
        print("sharded-bitmatch-ok")
    """)
    assert "sharded-bitmatch-ok" in stdout


def test_sharded_separation_triangles_bit_identical_4_devices():
    """separate() itself: per-shard candidate searches stitch back into
    exactly the single-device triangle set and chord allocation."""
    stdout = _run("""
        import numpy as np
        import jax
        from repro.core.cycles import separate
        from repro.core.graph import random_instance

        assert jax.device_count() == 4, jax.device_count()
        inst = random_instance(60, 0.2, seed=5, pad_edges=1024, pad_nodes=64)
        ref = separate(inst, max_neg=64, max_tri_per_edge=4,
                       with_cycles45=True, graph_impl="sparse",
                       separation_chunk=8)
        for shards in (2, 4):
            s = separate(inst, max_neg=64, max_tri_per_edge=4,
                         with_cycles45=True, graph_impl="sparse",
                         separation_chunk=8, separation_shards=shards)
            np.testing.assert_array_equal(np.asarray(ref.triangles.edges),
                                          np.asarray(s.triangles.edges))
            np.testing.assert_array_equal(np.asarray(ref.triangles.valid),
                                          np.asarray(s.triangles.valid))
            for f in ("u", "v", "cost", "edge_valid", "node_valid"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(ref.instance, f)),
                    np.asarray(getattr(s.instance, f)), err_msg=f)
        print("sharded-separate-ok")
    """)
    assert "sharded-separate-ok" in stdout


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs >= 2 devices in-process (CI 4-dev job)")
def test_sharded_solve_in_process_multi_device():
    """In-process shard_map path (runs under the CI job that forces 4
    virtual CPU devices): sharded == unsharded, bit for bit."""
    import dataclasses
    inst = random_instance(48, 0.25, seed=7, pad_edges=1024, pad_nodes=64)
    base = SolverConfig(graph_impl="sparse", max_neg=64, separation_chunk=8)
    ref = api.solve(inst, mode="pd", config=base)
    cfg = dataclasses.replace(base,
                              separation_shards=jax.device_count())
    r = api.solve(inst, mode="pd", config=cfg)
    assert np.asarray(r.labels).tolist() == np.asarray(ref.labels).tolist()
    assert float(r.objective) == float(ref.objective)
