"""Registry completeness: every assigned architecture is selectable with the
exact published configuration, and every (arch × shape) cell is defined."""
import jax.numpy as jnp
import pytest

import repro.configs  # noqa: F401
from repro.configs.base import REGISTRY, all_arch_ids, get_arch

ASSIGNED = {
    "granite-34b", "gemma2-9b", "phi3-mini-3.8b", "llama4-scout-17b-a16e",
    "grok-1-314b", "dimenet", "egnn", "mace", "graphcast", "wide-deep",
}


def test_all_assigned_archs_registered():
    missing = ASSIGNED - set(all_arch_ids())
    assert not missing, f"missing archs: {missing}"


def test_rama_arch_registered():
    assert "rama-multicut" in all_arch_ids()


def test_40_cells_defined():
    cells = sum(len(get_arch(a).shapes) for a in ASSIGNED)
    assert cells == 40


@pytest.mark.parametrize("aid", sorted(ASSIGNED))
def test_abstract_inputs_no_allocation(aid):
    """abstract_inputs must return ShapeDtypeStructs (never real arrays)."""
    import jax
    arch = get_arch(aid)
    for shape in arch.shapes.values():
        tree = arch.abstract_inputs(shape)
        for leaf in jax.tree.leaves(tree):
            assert isinstance(leaf, jax.ShapeDtypeStruct), (aid, shape.name)


def test_granite_exact_config():
    cfg = get_arch("granite-34b").cfg
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab) == (88, 6144, 48, 1, 24576, 49152)


def test_gemma2_exact_config():
    cfg = get_arch("gemma2-9b").cfg
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab) == (42, 3584, 16, 8, 14336, 256000)
    assert cfg.local_global_alternate and cfg.attn_softcap is not None


def test_phi3_exact_config():
    cfg = get_arch("phi3-mini-3.8b").cfg
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab) == (32, 3072, 32, 32, 8192, 32064)


def test_llama4_exact_config():
    cfg = get_arch("llama4-scout-17b-a16e").cfg
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab) == (48, 5120, 40, 8, 8192, 202048)
    assert cfg.moe and cfg.n_experts == 16 and cfg.top_k == 1


def test_grok_exact_config():
    cfg = get_arch("grok-1-314b").cfg
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab) == (64, 6144, 48, 8, 32768, 131072)
    assert cfg.moe and cfg.n_experts == 8 and cfg.top_k == 2


def test_gnn_exact_configs():
    dn = get_arch("dimenet").cfg
    assert (dn.n_blocks, dn.d_hidden, dn.n_bilinear, dn.n_spherical,
            dn.n_radial) == (6, 128, 8, 7, 6)
    egc = get_arch("egnn").cfg
    assert (egc.n_layers, egc.d_hidden) == (4, 64)
    mcc = get_arch("mace").cfg
    assert (mcc.n_layers, mcc.d_hidden, mcc.l_max, mcc.correlation,
            mcc.n_rbf) == (2, 128, 2, 3, 8)
    gcc = get_arch("graphcast").cfg
    assert (gcc.n_layers, gcc.d_hidden, gcc.mesh_refinement,
            gcc.n_vars) == (16, 512, 6, 227)


def test_widedeep_exact_config():
    cfg = get_arch("wide-deep").cfg
    assert (cfg.n_sparse, cfg.embed_dim, cfg.mlp_dims) == \
        (40, 32, (1024, 512, 256))


def test_lm_shape_cells():
    shapes = get_arch("granite-34b").shapes
    assert shapes["train_4k"].dims == dict(seq_len=4096, global_batch=256)
    assert shapes["prefill_32k"].dims == dict(seq_len=32768, global_batch=32)
    assert shapes["decode_32k"].dims == dict(seq_len=32768, global_batch=128)
    assert shapes["long_500k"].dims == dict(seq_len=524288, global_batch=1)
    assert shapes["decode_32k"].kind == "decode"   # lowers serve_step


def test_recsys_shape_cells():
    shapes = get_arch("wide-deep").shapes
    assert shapes["train_batch"].dims["batch"] == 65536
    assert shapes["serve_p99"].dims["batch"] == 512
    assert shapes["serve_bulk"].dims["batch"] == 262144
    assert shapes["retrieval_cand"].dims["n_candidates"] == 1_000_000


def test_grok_params_order_of_magnitude():
    """grok-1 is ~314B total params; our analytic count must land there."""
    cfg = get_arch("grok-1-314b").cfg
    assert 2.5e11 < cfg.params_count < 3.9e11


def test_model_flops_positive():
    for aid in sorted(ASSIGNED):
        arch = get_arch(aid)
        for shape in arch.shapes.values():
            assert arch.model_flops(shape) > 0, (aid, shape.name)
