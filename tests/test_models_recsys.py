import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs  # noqa: F401
from repro.configs.base import REGISTRY
from repro.models import recsys as rs
from repro.train.optimizer import OptimizerConfig, apply_update, init_opt_state


@pytest.fixture
def small_cfg():
    return dataclasses.replace(REGISTRY["wide-deep"].cfg,
                               vocab_per_field=100, mlp_dims=(32, 16))


def _batch(cfg, B=8, seed=0):
    rng = np.random.default_rng(seed)
    si = jnp.asarray(rng.integers(0, cfg.vocab_per_field,
                                  (B, cfg.n_sparse, cfg.multi_hot)),
                     jnp.int32)
    df = jnp.asarray(rng.normal(0, 1, (B, cfg.n_dense)), jnp.float32)
    lab = jnp.asarray(rng.integers(0, 2, (B,)), jnp.float32)
    return si, df, lab


def test_forward_shape(small_cfg):
    params = rs.init_params(small_cfg, jax.random.PRNGKey(0))
    si, df, _ = _batch(small_cfg)
    logit = rs.forward(small_cfg, params, si, df)
    assert logit.shape == (8,)
    assert bool(jnp.isfinite(logit).all())


def test_train_step_learns(small_cfg):
    """A few steps on a fixed batch must reduce the BCE loss."""
    params = rs.init_params(small_cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    si, df, lab = _batch(small_cfg)
    cfg_opt = OptimizerConfig(lr=1e-2, warmup_steps=0, weight_decay=0.0)

    def loss(p):
        return rs.loss_fn(small_cfg, p, si, df, lab)

    l0 = float(loss(params))
    for _ in range(20):
        l, grads = jax.value_and_grad(loss)(params)
        params, opt, _ = apply_update(cfg_opt, params, grads, opt)
    assert float(loss(params)) < l0


def test_retrieval_score_is_batched_dot():
    q = jnp.asarray(np.random.default_rng(0).normal(0, 1, (16,)), jnp.float32)
    cands = jnp.asarray(np.random.default_rng(1).normal(0, 1, (1000, 16)),
                        jnp.float32)
    got = rs.retrieval_score(q, cands)
    want = cands @ q
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_retrieval_topk_correct():
    q = jnp.ones((4,), jnp.float32)
    cands = jnp.asarray(np.eye(8, 4), jnp.float32) * \
        jnp.arange(1, 9, dtype=jnp.float32)[:, None]
    scores = rs.retrieval_score(q, cands)
    vals, idx = jax.lax.top_k(scores, 3)
    # candidate rows 3 (value 4), 2 (3), 1 (2)... actually eye(8,4) rows 0-3
    assert int(idx[0]) == 3


def test_wide_path_contributes(small_cfg):
    """Zeroing the deep MLP leaves the wide linear path active."""
    params = rs.init_params(small_cfg, jax.random.PRNGKey(0))
    params["mlp_w"] = [w * 0 for w in params["mlp_w"]]
    params["mlp_b"] = [b * 0 for b in params["mlp_b"]]
    si, df, _ = _batch(small_cfg)
    logit = rs.forward(small_cfg, params, si, df)
    assert float(jnp.abs(logit).max()) > 0, "wide path dead"
