"""Per-GNN-arch smoke tests (reduced configs, one train step, no NaNs) and
physics properties: EGNN/MACE energy invariance under E(3) transforms."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs  # noqa: F401
from repro.configs.base import REGISTRY, ShapeCell
from repro.models.gnn import egnn as eg, mace as mc
from repro.models.gnn.common import GraphBatch
from repro.train.optimizer import init_opt_state

TINY_MOL = ShapeCell("molecule", "train",
                     dict(n_nodes=8, n_edges=16, batch=4, d_feat=8,
                          task="energy"))
TINY_CLS = ShapeCell("full_graph_sm", "train",
                     dict(n_nodes=32, n_edges=64, d_feat=12, n_classes=5,
                          task="node_cls"))


def _batch_for(arch, shape, seed=0):
    rng = np.random.default_rng(seed)
    d = arch._dims(shape)
    N, E, G = d["N"], d["E"], d["G"]
    ins = {}
    for k, sd in arch.abstract_inputs(shape).items():
        if sd.dtype == jnp.int32:
            hi = {"edges_src": N, "edges_dst": N, "graph_ids": G,
                  "labels_i": d.get("n_classes", 2),
                  "tri_kj": E, "tri_ji": E}.get(k, N)
            ins[k] = jnp.asarray(rng.integers(0, hi, sd.shape), jnp.int32)
        elif sd.dtype == jnp.bool_:
            ins[k] = jnp.ones(sd.shape, bool)
        else:
            ins[k] = jnp.asarray(rng.normal(0, 1, sd.shape), jnp.float32)
    return ins


@pytest.mark.parametrize("aid,shape", [
    ("dimenet", TINY_MOL), ("egnn", TINY_MOL), ("mace", TINY_MOL),
    ("graphcast", TINY_CLS), ("dimenet", TINY_CLS), ("egnn", TINY_CLS),
])
def test_train_step_finite(aid, shape):
    arch = REGISTRY[aid]
    ins = _batch_for(arch, shape)
    params = arch.init_params(shape, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step = arch.step_fn(shape)
    p2, o2, metrics = step(params, opt, **ins)
    assert bool(jnp.isfinite(metrics["loss"])), aid
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(p2)), aid


def _rand_graph(key, n=10, e=24, d_feat=6):
    ks = jax.random.split(key, 4)
    return GraphBatch(
        nodes=jax.random.normal(ks[0], (n, d_feat)),
        edges_src=jax.random.randint(ks[1], (e,), 0, n),
        edges_dst=jax.random.randint(ks[2], (e,), 0, n),
        edge_feat=jnp.zeros((e, 1)),
        node_mask=jnp.ones(n, bool), edge_mask=jnp.ones(e, bool),
        graph_ids=jnp.zeros(n, jnp.int32), n_graphs=1,
        positions=jax.random.normal(ks[3], (n, 3)))


def _rotation(key):
    """Random rotation matrix via QR."""
    M = jax.random.normal(key, (3, 3))
    Q, R = jnp.linalg.qr(M)
    return Q * jnp.sign(jnp.diag(R))[None, :]


@pytest.mark.parametrize("model", ["egnn", "mace"])
def test_energy_e3_invariant(model):
    """Rotating + translating all positions must not change predicted
    energy (the models' equivariance contract)."""
    g = _rand_graph(jax.random.PRNGKey(0))
    R = _rotation(jax.random.PRNGKey(1))
    t = jnp.array([1.5, -2.0, 0.3])
    g_rot = g._replace(positions=g.positions @ R.T + t)
    if model == "egnn":
        cfg = eg.EGNNConfig(n_layers=2, d_hidden=16, d_in=6)
        params = eg.init_params(cfg, jax.random.PRNGKey(2))
        e1, _, _ = eg.forward(cfg, params, g)
        e2, _, _ = eg.forward(cfg, params, g_rot)
    else:
        cfg = mc.MACEConfig(n_layers=1, d_hidden=8, l_max=2, correlation=2,
                            n_rbf=4, d_in=6)
        params = mc.init_params(cfg, jax.random.PRNGKey(2))
        e1 = mc.forward(cfg, params, g)
        e2 = mc.forward(cfg, params, g_rot)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=2e-4,
                               atol=2e-4)


def test_egnn_coordinates_equivariant():
    """EGNN's updated coordinates must rotate WITH the input frame."""
    g = _rand_graph(jax.random.PRNGKey(3))
    R = _rotation(jax.random.PRNGKey(4))
    cfg = eg.EGNNConfig(n_layers=2, d_hidden=16, d_in=6)
    params = eg.init_params(cfg, jax.random.PRNGKey(5))
    _, _, x1 = eg.forward(cfg, params, g)
    _, _, x2 = eg.forward(cfg, params, g._replace(positions=g.positions @ R.T))
    np.testing.assert_allclose(np.asarray(x1 @ R.T), np.asarray(x2),
                               atol=1e-3)


def test_edge_mask_blocks_messages():
    """Masked edges contribute nothing: zeroing the mask on some edges ==
    removing them."""
    g = _rand_graph(jax.random.PRNGKey(6), n=8, e=16)
    cfg = eg.EGNNConfig(n_layers=1, d_hidden=8, d_in=6)
    params = eg.init_params(cfg, jax.random.PRNGKey(7))
    mask = g.edge_mask.at[8:].set(False)
    e1, _, _ = eg.forward(cfg, params, g._replace(edge_mask=mask))
    g_cut = g._replace(edges_src=g.edges_src[:8], edges_dst=g.edges_dst[:8],
                       edge_feat=g.edge_feat[:8], edge_mask=mask[:8])
    e2, _, _ = eg.forward(cfg, params, g_cut)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), atol=1e-4)


def test_graphcast_full_pipeline():
    """Native encoder→processor→decoder path on a tiny topology."""
    from repro.models.gnn import graphcast as gc
    cfg = gc.GraphCastConfig(n_layers=2, d_hidden=16, mesh_refinement=1,
                             n_vars=5, grid_lat=6, grid_lon=8)
    topo = gc.build_topology(cfg, seed=0)
    params = gc.init_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (cfg.n_grid, cfg.n_vars))
    out = gc.forward(cfg, params, x, topo)
    assert out.shape == (cfg.n_grid, cfg.n_vars)
    assert bool(jnp.isfinite(out).all())
