"""Distributed solver + sharding tests. Multi-device cases run in
subprocesses so the parent process keeps its single real CPU device
(XLA device count is locked at first jax init)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_dist_pd_round_runs_and_lb_valid():
    stdout = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_debug_mesh
        from repro import api
        from repro.core.dist import (make_dist_pd_round, partition_instance,
                                     merge_blocks_quotient)
        from repro.core.graph import random_instance
        from repro.core.solver import SolverConfig

        mesh = make_debug_mesh(4, 2)
        inst = random_instance(400, 0.05, seed=3, pad_edges=8192,
                               pad_nodes=512)
        parts = partition_instance(inst, 8, 64, 1024)
        rnd = make_dist_pd_round(mesh, mp_iters=3, max_neg=64)
        ins = {k: jnp.asarray(v) for k, v in parts.items()
               if k in ("u","v","cost","edge_valid","node_valid",
                        "boundary_cost")}
        out = rnd(ins["u"], ins["v"], ins["cost"], ins["edge_valid"],
                  ins["node_valid"], ins["boundary_cost"])
        lb_dist = float(out[6][0])
        # global solve for comparison: the dist LB must lower-bound the
        # single-device PD primal objective (any feasible solution)
        r = api.solve(inst, mode="pd", config=SolverConfig(max_neg=512))
        assert lb_dist <= r.objective + 1e-3, (lb_dist, r.objective)
        # quotient merge produces a coherent instance
        labels = np.asarray(out[5])
        q, gl = merge_blocks_quotient(labels, parts["boundary_u"],
                                      parts["boundary_v"],
                                      parts["boundary_cost"], 64, 4096)
        assert int(np.asarray(q.node_valid).sum()) > 0
        print("LB", lb_dist, "obj", r.objective)
    """)
    assert "LB" in stdout


def test_lm_train_step_shards_on_debug_mesh():
    """Lower+compile the reduced granite train step on a 2x2 mesh —
    the in/out shardings must be accepted and the HLO must contain a
    gradient all-reduce."""
    _run("""
        import dataclasses, jax, jax.numpy as jnp
        import repro.configs
        from repro.configs.base import REGISTRY
        from repro.launch.mesh import make_debug_mesh
        from repro.models import transformer as tfm
        from repro.train.optimizer import init_opt_state, apply_update, OptimizerConfig

        arch = REGISTRY["granite-34b"]
        cfg = dataclasses.replace(arch.cfg, n_layers=2, d_model=64, n_heads=4,
                                  n_kv_heads=1, head_dim=16, d_ff=128,
                                  vocab=256, remat=False,
                                  act_sharding=(("data",), None, "model"))
        mesh = make_debug_mesh(2, 2)
        from jax.sharding import NamedSharding, PartitionSpec as P
        pspecs = tfm.param_pspecs(cfg)
        arch2 = dataclasses.replace(arch, cfg=cfg)
        pp = arch2._filter_axes(mesh, pspecs)
        pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pp,
                              is_leaf=lambda x: isinstance(x, P))
        def train_step(params, tokens, targets):
            def loss(p):
                return tfm.loss_fn(cfg, p, tokens, targets)
            l, g = jax.value_and_grad(loss)(params)
            return l, g
        tok = jax.ShapeDtypeStruct((8, 16), jnp.int32)
        params_abs = jax.eval_shape(lambda: tfm.init_params(cfg, jax.random.PRNGKey(0)))
        dshard = NamedSharding(mesh, P("data", None))
        # set_mesh only exists on newer jax; old Mesh is its own context
        ctx = (jax.sharding.set_mesh(mesh)
               if hasattr(jax.sharding, "set_mesh") else mesh)
        with ctx:
            lowered = jax.jit(train_step,
                              in_shardings=(pshard, dshard, dshard)).lower(
                params_abs, tok, tok)
            compiled = lowered.compile()
        hlo = compiled.as_text()
        assert "all-reduce" in hlo or "all-gather" in hlo, "no collective!"
        print("collectives present")
    """, devices=4)


def test_recsys_table_sharding_compiles():
    _run("""
        import dataclasses, jax, jax.numpy as jnp
        import repro.configs
        from repro.configs.base import REGISTRY, ShapeCell
        from repro.launch.mesh import make_debug_mesh
        arch = REGISTRY["wide-deep"]
        arch = dataclasses.replace(
            arch, cfg=dataclasses.replace(arch.cfg, vocab_per_field=1024,
                                          mlp_dims=(64, 32)))
        mesh = make_debug_mesh(2, 2)
        shape = ShapeCell("train_batch", "train", dict(batch=64))
        step = arch.step_fn(shape)
        params = arch.abstract_params()
        opt = arch.abstract_opt()
        ss = arch.state_shardings(mesh, shape)
        ins = arch.abstract_inputs(shape)
        ishard = arch.input_shardings(mesh, shape)
        lowered = jax.jit(step, in_shardings=(ss["params"], ss["opt"],
                                              ishard["sparse_idx"],
                                              ishard["dense_feats"],
                                              ishard["labels"])).lower(
            params, opt, ins["sparse_idx"], ins["dense_feats"], ins["labels"])
        compiled = lowered.compile()
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else ca   # old jax returns a list
        print("ok", ca["flops"])
    """, devices=4)
