"""Request-lifecycle tracing (PR 10): SpanRecorder + engine integration.

Two layers: the recorder itself (append-only, bounded, valid Chrome
Trace Event Format out), and the engine wiring — a ``tracer=`` engine
stamps admit → flush → dispatch → queued/solve → harvest → demux spans
with per-request swimlanes, driven entirely through the injectable
clock (no sleeps), and recording must not change results or stats.
"""
import json

import numpy as np
import pytest

from repro.core.graph import random_instance
from repro.core.solver import SolverConfig
from repro.obs import MetricsRegistry, SpanRecorder
from repro.serve import BucketPolicy, Route, SolveEngine

CFG = SolverConfig(max_neg=32, mp_iters=2, max_rounds=4, graph_impl="dense")
ROUTE = Route(mode="pd", config=CFG)
POLICY = BucketPolicy(node_floor=16, edge_floor=64)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def advance(self, dt):
        self.t += dt

    def __call__(self):
        return self.t


def _small(seed):
    return random_instance(12, 0.5, seed=seed, pad_edges=64, pad_nodes=16)


# ---------------------------------------------------------------------------
# recorder
# ---------------------------------------------------------------------------

def test_recorder_records_and_clamps():
    rec = SpanRecorder()
    rec.record_span("solve", 1.0, 3.5, tid=2, nodes=12)
    rec.record_span("backwards", 5.0, 4.0)          # t1 < t0 clamps to 0
    rec.record_instant("admit", 0.5, tid=2)
    assert len(rec) == 3
    assert rec.spans[0].dur_s == pytest.approx(2.5)
    assert rec.spans[1].dur_s == 0.0
    assert rec.spans[2].dur_s is None
    assert rec.spans[0].args == {"nodes": 12}


def test_recorder_overflow_drops_and_counts():
    rec = SpanRecorder(max_events=2)
    for i in range(5):
        rec.record_instant("x", float(i))
    assert len(rec) == 2
    assert rec.n_dropped == 3
    rec.clear()
    assert len(rec) == 0 and rec.n_dropped == 0
    with pytest.raises(ValueError):
        SpanRecorder(max_events=0)


def test_chrome_trace_format_is_valid():
    rec = SpanRecorder()
    rec.record_instant("admit", 10.0, tid=1)
    rec.record_span("solve", 10.5, 11.0, tid=1)
    rec.record_span("harvest", 11.0, 11.2)          # engine lane
    doc = json.loads(rec.to_json())
    events = doc["traceEvents"]
    # metadata names the process, the engine lane, and each request lane
    meta = [e for e in events if e["ph"] == "M"]
    names = {(e["name"], e["tid"]): e["args"]["name"] for e in meta}
    assert names[("process_name", 0)] == "repro.serve"
    assert names[("thread_name", 0)] == "engine"
    assert names[("thread_name", 1)] == "req 1"
    # timestamps are µs offsets from the earliest event
    real = [e for e in events if e["ph"] != "M"]
    assert min(e["ts"] for e in real) == 0.0
    by_name = {e["name"]: e for e in real}
    assert by_name["admit"]["ph"] == "i"
    assert by_name["admit"]["s"] == "t"
    assert by_name["solve"]["ph"] == "X"
    assert by_name["solve"]["dur"] == pytest.approx(0.5e6)
    assert doc["otherData"] == {"n_spans": 3, "n_dropped": 0}


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

def _traced_engine(clock):
    return SolveEngine(policy=POLICY, batch_cap=2, flush_timeout_s=None,
                       clock=clock, tracer=SpanRecorder(),
                       metrics=MetricsRegistry())


def test_engine_stamps_full_request_lifecycle():
    clock = FakeClock()
    eng = _traced_engine(clock)
    # 5 requests at cap 2: two full batches dispatch from submit, the
    # odd one out rides the forced partial flush (a "flush" instant)
    insts = [_small(s) for s in range(5)]
    tickets = []
    for inst in insts:
        tickets.append(eng.submit(inst, route=ROUTE))
        clock.advance(0.01)
    eng.flush()
    eng.drain()          # blocking harvest: flush alone leaves it in flight
    assert all(t.done for t in tickets)

    rec = eng.tracer
    names = {s.name for s in rec.spans}
    assert {"admit", "flush", "dispatch", "queued", "solve",
            "harvest", "demux"} <= names
    # per-request lanes: every ticket's req_id shows admit+queued+solve
    for t in tickets:
        lane = [s.name for s in rec.spans if s.tid == t.req_id]
        assert "admit" in lane and "queued" in lane and "solve" in lane
    # req ids are unique, monotone, and never collide with the engine lane
    ids = [t.req_id for t in tickets]
    assert ids == sorted(ids) and len(set(ids)) == len(ids)
    assert all(i >= 1 for i in ids)
    # engine-lane events carry tid 0
    assert {s.tid for s in rec.spans if s.name in ("harvest", "demux",
                                                   "flush", "dispatch")} \
        == {SpanRecorder.ENGINE_TID}
    # spans share the fake-clock timebase
    assert all(0.0 <= s.t0_s <= clock.t for s in rec.spans)


def test_tracing_does_not_change_results_or_stats():
    insts = [_small(s) for s in range(4)]
    plain = SolveEngine(policy=POLICY, batch_cap=2, flush_timeout_s=None)
    r_plain = plain.solve_stream(insts)
    traced = _traced_engine(FakeClock())
    r_traced = traced.solve_stream(insts)
    for a, b in zip(r_plain, r_traced):
        assert np.asarray(a.labels).tobytes() == np.asarray(b.labels).tobytes()
        assert float(a.objective) == float(b.objective)
    assert plain.stats.n_dispatches == traced.stats.n_dispatches
    assert plain.stats.latency_hist.count == traced.stats.latency_hist.count


def test_engine_metrics_cover_queue_and_latency():
    clock = FakeClock()
    eng = _traced_engine(clock)
    for s in range(3):
        eng.submit(_small(s), route=ROUTE)
        clock.advance(0.5)
    eng.flush()
    eng.drain()          # blocking harvest: every ticket demuxed
    snap = eng.metrics_snapshot()
    assert snap["engine_requests_submitted"]["value"] == 3
    assert snap["engine_requests_completed"]["value"] == 3
    assert snap["engine_queue_depth"]["value"] == 0
    assert snap["request_latency_seconds"]["count"] == 3
    # fake clock: the last request waited ~0.5s, the first ~1.5s
    assert snap["request_latency_seconds"]["max"] >= \
        snap["request_latency_seconds"]["min"]
    prom = eng.metrics_prometheus()
    assert "# TYPE engine_queue_depth gauge" in prom
    assert "request_latency_seconds_count 3" in prom


def test_deadline_miss_recorded_as_instant():
    clock = FakeClock()
    eng = _traced_engine(clock)
    t = eng.submit(_small(0), route=ROUTE, deadline_s=1.0)
    clock.advance(5.0)                   # blow the deadline before flushing
    eng.flush()
    eng.drain()          # blocking harvest: flush alone leaves it in flight
    assert t.done
    misses = [s for s in eng.tracer.spans if s.name == "deadline_miss"]
    assert len(misses) == 1
    assert misses[0].tid == t.req_id
    assert misses[0].args["late_s"] == pytest.approx(4.0)
    assert eng.metrics_snapshot()["engine_deadline_missed"]["value"] == 1
